"""SLO-aware preemption: priority classes, victim selection, KV page
spill/restore, and the headline guarantee — a preempted-then-restored
request streams token-identical to an uninterrupted run.

Three layers, mirroring the serving stack:

  * fake-executor scheduler tests — a deterministic token chain (next
    token is a pure function of the previous token and its position)
    makes any scheduling interleaving comparable to a sequential
    reference with no model in the loop: victim ordering, priority
    admission, restore-at-watermark with zero recompute, rollback-then-
    spill under speculative decoding, optimistic pressure relief;
  * engine tests on the tiny smoke model — organic preemption through
    the REAL device gather/scatter spill tier across int8 KV × prefix
    sharing × ngram spec decode, manual `preempt()` between prefill
    chunks of a long prompt, all greedy-identical to a no-preemption
    reference engine;
  * a forced 2-way mesh subprocess (same pattern as
    test_sharded_serving) proving spill/restore through the replicated
    host-tier shardings keeps tensor-parallel streams identical.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.serving.kv_pager import KVPager, PageAllocationError, PagerConfig
from repro.serving.scheduler import (Request, Scheduler, SpillRecord,
                                     _Preempted, _SlotState)


# ---------------------------------------------------------------------------
# Deterministic-chain fake executor: the "model" is a pure function of
# (input token, position), so token identity across preemption is exact
# ---------------------------------------------------------------------------

def _chain(tok: int, pos: int) -> int:
    return (tok * 7 + pos) % 1000 + 1


def _ref_stream(prompt: np.ndarray, max_new: int) -> list[int]:
    """The uninterrupted sequential greedy stream of the chain model."""
    out, last, q = [], int(prompt[-1]), len(prompt) - 1
    for _ in range(max_new):
        last = _chain(last, q)
        out.append(last)
        q += 1
    return out


class _ChainExec:
    """run_batch over the chain model, incl. the draft/verify contract:
    leading drafts matching the chain are accepted, the corrected/bonus
    token is the chain value at the first mismatch (or one past a full
    acceptance) — exactly the acceptance-sampling greedy semantics."""

    def __init__(self):
        self.calls = 0

    def run_batch(self, tokens, pos, row_slots, sample_idx, temps, topks,
                  n_draft=None):
        self.calls += 1
        b = tokens.shape[0]
        fix = np.zeros(b, np.int32)
        acc = np.zeros(b, np.int32)
        for r in range(b):
            i = int(sample_idx[r])
            nd = 0 if n_draft is None else int(n_draft[r])
            j = 0
            while j < nd and int(tokens[r, i + j + 1]) == _chain(
                    int(tokens[r, i + j]), int(pos[r, i + j])):
                j += 1
            acc[r] = j
            fix[r] = _chain(int(tokens[r, i + j]), int(pos[r, i + j]))
        return fix if n_draft is None else (fix, acc)


def _sched(num_slots=2, pages_per_slot=4, page_size=4, num_pages=9,
           optimistic=False, chunk=4, preemption=True, **kw):
    pager = KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                                num_slots=num_slots,
                                pages_per_slot=pages_per_slot,
                                optimistic=optimistic))
    ex = _ChainExec()
    return Scheduler(pager, run_batch=ex.run_batch, chunk_size=chunk,
                     preemption=preemption, **kw), ex


def _prompt(rid: int, n: int) -> np.ndarray:
    return ((np.arange(n) * 13 + rid * 101) % 900 + 1).astype(np.int32)


def _assert_drained(sched):
    assert sched.pager.pages_in_use == 0
    assert sched.pager._reserved == 0
    assert not sched.pager.spill_records
    assert not sched.preempted


# ---------------------------------------------------------------------------
# Scheduler: priority admission + organic preemption
# ---------------------------------------------------------------------------

def test_priority_orders_queue_fifo_within_class():
    sched, _ = _sched(num_pages=99, num_slots=2)
    for rid, pri in [(0, 0), (1, 2), (2, 1), (3, 2)]:
        sched.submit(Request(rid=rid, tokens=_prompt(rid, 4),
                             max_new_tokens=2, priority=pri))
    assert [r.rid for r in sched.queue] == [1, 3, 2, 0]


def test_high_priority_preempts_and_all_streams_identical():
    """2 slots / 8 usable pages fully held by low-priority requests; two
    high-priority arrivals must evict them via spill, and every stream —
    preempted or not — matches its uninterrupted chain reference."""
    sched, _ = _sched(num_slots=2, pages_per_slot=4, page_size=4,
                      num_pages=9)
    lo = [Request(rid=r, tokens=_prompt(r, 4), max_new_tokens=12,
                  priority=0) for r in (0, 1)]     # 15 tok → 4 pages each
    for r in lo:
        sched.submit(r)
    for _ in range(3):
        sched.step()                 # prefill + a little decode progress
    hi = [Request(rid=r, tokens=_prompt(r, 4), max_new_tokens=4,
                  priority=1) for r in (2, 3)]
    for r in hi:
        sched.submit(r)
    out = sched.run()
    assert sched.stats.preemptions >= 2
    assert sched.stats.restores == sched.stats.preemptions
    assert sched.stats.spilled_pages == sched.stats.restored_pages > 0
    for req in lo + hi:
        assert list(out[req.rid]) == _ref_stream(req.tokens,
                                                 req.max_new_tokens), req.rid
    # zero recompute: every prompt token ran through the model exactly once
    assert sched.stats.prefill_tokens == sum(
        len(r.tokens) for r in lo + hi)
    _assert_drained(sched)


def test_victim_selection_lowest_class_most_pages_least_progress():
    sched, _ = _sched(num_slots=3, pages_per_slot=4, page_size=4,
                      num_pages=99)
    # A: pri 0, 12-token prompt (3 pages); B: pri 0, 4 tokens (1 page);
    # C: pri 1, 4 tokens — admitted together, one decode step each
    sched.submit(Request(rid=0, tokens=_prompt(0, 12), max_new_tokens=4,
                         priority=0))
    sched.submit(Request(rid=1, tokens=_prompt(1, 4), max_new_tokens=4,
                         priority=0))
    sched.submit(Request(rid=2, tokens=_prompt(2, 4), max_new_tokens=4,
                         priority=1))
    sched.step()
    slot_of = {st.request.rid: s for s, st in sched.slots.items()}
    # lowest class first, most pages breaks the tie → A
    assert sched._pick_victim(below=2) == slot_of[0]
    # nothing strictly below priority 0
    assert sched._pick_victim(below=0) is None


def test_victim_tiebreak_least_progress():
    sched, _ = _sched(num_slots=2, pages_per_slot=4, page_size=4,
                      num_pages=99)
    # same prompt length (same page count); A is 1/10 done, B is 1/2 done
    sched.submit(Request(rid=0, tokens=_prompt(0, 4), max_new_tokens=10))
    sched.submit(Request(rid=1, tokens=_prompt(1, 4), max_new_tokens=2))
    sched.step()
    slot_of = {st.request.rid: s for s, st in sched.slots.items()}
    assert all(len(st.generated) == 1 for st in sched.slots.values())
    assert sched._pick_victim(below=1) == slot_of[0]   # least progress


def test_restore_preferred_over_queue_within_class():
    """A parked request is re-admitted before an equal-priority queued
    one: it holds committed KV, so restoring first wastes nothing."""
    sched, _ = _sched(num_slots=1, pages_per_slot=4, page_size=4,
                      num_pages=5)
    sched.submit(Request(rid=0, tokens=_prompt(0, 4), max_new_tokens=12,
                         priority=0))
    for _ in range(2):
        sched.step()
    assert sched.preempt_request(0)
    sched.submit(Request(rid=1, tokens=_prompt(1, 4), max_new_tokens=2,
                         priority=0))
    sched.step()
    # rid 0 restored into the lone slot; rid 1 still queued behind it
    active = [st.request.rid for st in sched.slots.values()]
    assert active == [0] and [r.rid for r in sched.queue] == [1]
    out = sched.run()
    assert list(out[0]) == _ref_stream(_prompt(0, 4), 12)
    assert list(out[1]) == _ref_stream(_prompt(1, 4), 2)
    _assert_drained(sched)


def test_preempt_between_prefill_chunks_resumes_at_watermark():
    """Spilling a mid-prefill request and restoring it must resume at the
    NEXT chunk: total prompt tokens dispatched stays exactly Σ|prompt|."""
    sched, _ = _sched(num_slots=1, pages_per_slot=8, page_size=4,
                      num_pages=17, chunk=4)
    req = Request(rid=0, tokens=_prompt(0, 24), max_new_tokens=4)
    sched.submit(req)
    sched.step()                          # chunked prefill begins
    st = next(iter(sched.slots.values()))
    assert 0 < st.committed < 24          # genuinely mid-prefill
    done_before = st.committed
    assert sched.preempt_request(0)
    assert sched.stats.preemptions == 1
    out = sched.run()
    assert list(out[0]) == _ref_stream(req.tokens, 4)
    # zero recompute across the spill: no chunk ran twice
    assert sched.stats.prefill_tokens == 24
    assert sched.stats.restores == 1
    assert done_before < 24               # the spill split the prefill
    _assert_drained(sched)


def test_preempt_mid_spec_run_rollback_then_spill():
    """A verify step that truncated rejected drafts, immediately followed
    by a spill of the same slot, then restore — stream stays identical
    and rollback/spill page accounting composes cleanly."""
    def draft(reqs):
        out = {}
        for slot, _rid, ctx, q, k in reqs:
            # first draft follows the chain (accepted), rest are garbage
            # (rejected) → every verify run rolls back
            good = _chain(int(ctx[-1]), q)
            out[slot] = [good] + [999] * (k - 1) if k >= 2 else [good]
        return out

    sched, _ = _sched(num_slots=1, pages_per_slot=8, page_size=4,
                      num_pages=17, spec_decode="draft_fn", spec_k=3,
                      draft_fn=draft)
    req = Request(rid=0, tokens=_prompt(0, 4), max_new_tokens=12)
    sched.submit(req)
    for _ in range(3):
        sched.step()                      # prefill + verify runs
    assert sched.stats.rollbacks > 0      # drafts were rejected
    assert sched.preempt_request(0)       # spill right after a rollback
    out = sched.run()
    assert list(out[0]) == _ref_stream(req.tokens, 12)
    assert sched.stats.preemptions == 1 and sched.stats.restores == 1
    _assert_drained(sched)


def test_optimistic_admission_completes_under_pressure():
    """Worst-case 30 pages of demand in a 12-usable-page pool: reserved
    admission would serialize; optimistic admits all three and relieves
    pressure by spilling, with every stream still chain-identical."""
    sched, _ = _sched(num_slots=3, pages_per_slot=10, page_size=4,
                      num_pages=13, optimistic=True)
    reqs = [Request(rid=r, tokens=_prompt(r, 4), max_new_tokens=37)
            for r in range(3)]            # 40 tokens → 10 pages each
    for r in reqs:
        sched.submit(r)
    sched.step()
    assert sched.num_active == 3          # reserved mode could only fit 1
    out = sched.run()
    assert sched.stats.pressure_spills > 0
    for r in reqs:
        assert list(out[r.rid]) == _ref_stream(r.tokens, 37)
    _assert_drained(sched)


def test_reserved_admission_serializes_same_load():
    """The baseline the optimistic mode beats: same 30-page demand under
    worst-case reservation admits one request at a time."""
    sched, _ = _sched(num_slots=3, pages_per_slot=10, page_size=4,
                      num_pages=13, optimistic=False, preemption=False)
    for r in range(3):
        sched.submit(Request(rid=r, tokens=_prompt(r, 4),
                             max_new_tokens=37))
    sched.step()
    assert sched.num_active == 1
    out = sched.run()
    assert sched.stats.preemptions == 0
    for r in range(3):
        assert list(out[r]) == _ref_stream(_prompt(r, 4), 37)


def test_manual_preempt_hook_edge_cases():
    sched, _ = _sched(num_pages=99)
    sched.submit(Request(rid=0, tokens=_prompt(0, 4), max_new_tokens=4))
    sched.step()
    assert not sched.preempt_request(77)      # unknown rid
    no_pre, _ = _sched(num_pages=99, preemption=False)
    with pytest.raises(ValueError, match="preemption"):
        no_pre.preempt_request(0)
    # optimistic admission without preemption is rejected at construction
    with pytest.raises(ValueError, match="optimistic"):
        _sched(optimistic=True, preemption=False)
    # spill_fn sees exactly the pages the pager then spills
    seen = {}
    sched2, _ = _sched(num_pages=99,
                       spill_fn=lambda ids: seen.setdefault("ids",
                                                            list(ids)),
                       restore_fn=lambda h, fresh: seen.setdefault(
                           "fresh", list(fresh)))
    sched2.submit(Request(rid=0, tokens=_prompt(0, 6), max_new_tokens=8))
    for _ in range(3):
        sched2.step()
    assert sched2.preempt_request(0)
    rec = sched2.preempted[0].record
    assert seen["ids"] == rec.spilled_pages
    sched2.run()
    assert len(seen["fresh"]) == len(seen["ids"])


def test_run_raises_when_parked_request_can_never_be_placed():
    """The wedge guard: a parked request whose record can never restore
    (dead/oversized) must fail loudly instead of spinning forever."""
    sched, _ = _sched(num_pages=9)
    state = _SlotState(request=Request(rid=9, tokens=_prompt(9, 4),
                                       max_new_tokens=4),
                       generated=[5], committed=4)
    # a record the pager does not know about is never restorable
    rec = SpillRecord(spill_id=123, layout=[("spilled", 0)],
                      spilled_pages=[7], slot_len=5, committed=4,
                      reserved=0)
    sched.preempted.append(_Preempted(state=state, record=rec, handle=None,
                                      seq=0))
    with pytest.raises(RuntimeError, match="wedged"):
        sched.run()


def test_stats_surface_counts_spill_traffic():
    sched, _ = _sched(num_slots=2, pages_per_slot=4, page_size=4,
                      num_pages=9)
    for r in (0, 1):
        sched.submit(Request(rid=r, tokens=_prompt(r, 4),
                             max_new_tokens=12, priority=0))
    for _ in range(3):
        sched.step()
    sched.submit(Request(rid=2, tokens=_prompt(2, 4), max_new_tokens=4,
                         priority=1))
    sched.run()
    st = sched.stats
    assert st.preemptions >= 1
    assert st.restores == st.preemptions
    assert st.restored_pages == st.spilled_pages >= st.restores
    assert st.restore_time_s > 0.0


# ---------------------------------------------------------------------------
# Engine: preemption through the real device spill tier (tiny smoke model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_seq", 128)
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return GenerationEngine(m, params, **kw)


def _per_request_refs(m, params, cfg, prompts, max_new, **kw):
    """Uninterrupted reference streams: a no-preemption engine with an
    ample pool serves each request alone (same kv_quant etc. as the
    engine under test, so the comparison isolates preemption)."""
    eng = _engine(m, params, num_slots=2, num_pages=64, **kw)
    refs = []
    for p in prompts:
        rid = eng.submit(p, max_new)
        refs.append([int(t) for t in eng.drain()[rid]])
    return refs


def test_engine_preemption_identity_int8_prefix_spec(model_and_params):
    """Organic SLO preemption end-to-end: int8 KV pools × prefix sharing
    × ngram speculative decoding × optimistic admission, with the real
    jit'd gather/scatter spill executors moving page bytes through the
    host tier. Every stream must match the no-preemption reference."""
    cfg, m, params = model_and_params
    feats = dict(kv_quant="int8", spec_decode="ngram", spec_k=4)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    longs = [np.concatenate([prefix, rng.integers(
        0, cfg.vocab_size, (t,)).astype(np.int32)]) for t in (5, 9)]
    shorts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
              for t in (6, 4)]
    eng = _engine(m, params, num_pages=14, preemption=True,
                  admission="optimistic", **feats)
    lo = [eng.submit(p, 24, prefix_id="sys", priority=0) for p in longs]
    for _ in range(4):
        eng.step()
    hi = [eng.submit(p, 8, priority=1) for p in shorts]
    out = eng.drain()
    st = eng.stats()
    assert st.preemptions >= 1 and st.restores == st.preemptions
    assert st.spilled_pages == st.restored_pages > 0
    assert st.pages_spilled_now == 0          # host tier fully drained
    assert st.restore_ms_mean > 0.0
    assert st.pager.pages_used == 0
    ref_lo = _per_request_refs(m, params, cfg, longs, 24, **feats)
    ref_hi = _per_request_refs(m, params, cfg, shorts, 8, **feats)
    for rid, ref in zip(lo + hi, ref_lo + ref_hi):
        assert [int(t) for t in out[rid]] == ref, f"rid {rid} diverged"


def test_engine_manual_preempt_between_chunks(model_and_params):
    """`engine.preempt(rid)` mid-prefill of a long prompt: the restored
    request resumes at the commit watermark (no chunk re-runs) and the
    float-KV stream matches `generate()` exactly."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, (40,)).astype(np.int32)
    eng = _engine(m, params, num_slots=2, num_pages=32, prefill_chunk=4,
                  preemption=True)
    rid = eng.submit(prompt, 8)
    eng.step()                                # first prefill chunks land
    assert eng.preempt(rid)                   # spill mid-prefill
    assert not eng.preempt(999)               # unknown rid
    out = eng.drain()
    import jax.numpy as jnp
    ref = eng.generate({"tokens": jnp.asarray(prompt)[None, :]}, 8)[0]
    np.testing.assert_array_equal(out[rid], np.asarray(ref))
    sst = eng.scheduler_stats
    assert sst.preemptions >= 1 and sst.restores == sst.preemptions
    # zero recompute: the 40 prompt tokens each ran exactly once
    assert sst.prefill_tokens + sst.prefill_tokens_skipped == 40


def test_engine_rejects_bad_preemption_configs(model_and_params):
    cfg, m, params = model_and_params
    with pytest.raises(ValueError, match="optimistic"):
        _engine(m, params, admission="optimistic")      # needs preemption
    with pytest.raises(ValueError, match="admission"):
        _engine(m, params, admission="yolo")
    eng = _engine(m, params, preemption=True, chunked_prefill=False)
    with pytest.raises(ValueError, match="chunked"):
        eng.submit(np.arange(4, dtype=np.int32), 4)


# ---------------------------------------------------------------------------
# Forced 2-way mesh: spill/restore through the replicated host-tier path
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import json
import jax
import numpy as np
import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine

cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
out = {"device_count": jax.device_count()}

rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
           for t in (9, 13, 6, 4)]
FEATS = dict(max_seq=128, num_slots=2, page_size=8, prefill_chunk=8,
             kv_quant="int8")


def serve_preempted(mesh):
    eng = GenerationEngine(m, params, num_pages=14, mesh=mesh,
                           preemption=True, admission="optimistic",
                           **FEATS)
    lo = [eng.submit(p, 16, priority=0) for p in prompts[:2]]
    for _ in range(3):
        eng.step()
    eng.preempt(lo[0])                      # force one spill through the
    hi = [eng.submit(p, 6, priority=1)      # mesh gather; more may follow
          for p in prompts[2:]]             # organically from priorities
    streams = eng.drain()
    st = eng.stats()
    return ([[int(t) for t in streams[r]] for r in lo + hi],
            dict(preemptions=st.preemptions, restores=st.restores,
                 spilled=st.spilled_pages, restored=st.restored_pages,
                 left=st.pages_spilled_now, used=st.pager.pages_used))


def serve_ref(mesh):
    refs = []
    for p, n in zip(prompts, (16, 16, 6, 6)):
        eng = GenerationEngine(m, params, num_pages=64, mesh=mesh, **FEATS)
        rid = eng.submit(p, n)
        refs.append([int(t) for t in eng.drain()[rid]])
    return refs


mesh = serving_mesh(2)
got, st = serve_preempted(mesh)
ref = serve_ref(mesh)
out["identical"] = got == ref
out.update(st)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_preempted_streams_identical(mesh_result):
    assert mesh_result["device_count"] == 2
    assert mesh_result["preemptions"] >= 1
    assert mesh_result["restores"] == mesh_result["preemptions"]
    assert mesh_result["spilled"] == mesh_result["restored"]
    assert mesh_result["left"] == 0 and mesh_result["used"] == 0
    assert mesh_result["identical"], "2-way mesh preempted streams diverged"
