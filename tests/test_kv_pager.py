"""KVPager: page alloc/free/reuse accounting + commit scatter layout."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_pager import (KVPager, PageAllocationError, PagerConfig,
                                    commit_prefill)


def _pager(num_pages=17, page_size=4, num_slots=4, pages_per_slot=4):
    return KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                               num_slots=num_slots,
                               pages_per_slot=pages_per_slot))


def test_alloc_free_roundtrip_accounting():
    p = _pager()
    assert p.num_free_pages == 16 and p.pages_in_use == 0
    slot, pages = p.alloc_slot(prompt_len=6, max_new_tokens=5)
    # 6-token prompt at P=4 → 2 pages now; 6+5-1=10 tokens → 3 total, 1 held
    assert len(pages) == 2
    assert p.pages_in_use == 2
    assert p.num_free_pages == 14
    assert p.slot_reserved[slot] == 1
    p.extend(slot, 9)                      # 9 tokens → 3rd page drawn
    assert p.pages_in_use == 3 and p.slot_reserved[slot] == 0
    p.free_slot(slot)
    assert p.pages_in_use == 0 and p.num_free_pages == 16
    assert p.num_free_slots == 4
    assert (p.page_tables[slot] == 0).all()   # back to scratch mapping


def test_page_exclusivity_and_reuse():
    p = _pager()
    s1, pg1 = p.alloc_slot(4, 1)
    s2, pg2 = p.alloc_slot(4, 1)
    assert not set(pg1) & set(pg2)
    assert 0 not in pg1 + pg2              # scratch page never handed out
    p.free_slot(s1)
    s3, pg3 = p.alloc_slot(8, 1)
    # LIFO free list: the freed page is reused first
    assert pg1[0] in pg3
    assert not set(pg3) & set(pg2)


def test_admission_respects_reservations():
    # 5 usable pages; first request reserves 4 (16 tokens worst case)
    p = _pager(num_pages=6, page_size=4, num_slots=2, pages_per_slot=4)
    s1, _ = p.alloc_slot(prompt_len=4, max_new_tokens=13)   # 16 tok → 4 pages
    assert p.slot_reserved[s1] == 3
    # one unreserved page left → an 8-token request must be refused
    assert not p.can_admit(prompt_len=5, max_new_tokens=4)
    assert p.can_admit(prompt_len=4, max_new_tokens=1)
    with pytest.raises(PageAllocationError):
        p.alloc_slot(prompt_len=5, max_new_tokens=4)
    # after the big request frees, admission succeeds again
    p.free_slot(s1)
    assert p.can_admit(prompt_len=5, max_new_tokens=4)


def test_over_capacity_request_rejected():
    p = _pager(pages_per_slot=2, page_size=4)   # 8-token slot capacity
    assert not p.can_admit(prompt_len=6, max_new_tokens=4)
    with pytest.raises(PageAllocationError):
        p.alloc_slot(6, 4)


def test_extend_cannot_outgrow_reservation():
    p = _pager()
    slot, _ = p.alloc_slot(prompt_len=4, max_new_tokens=1)  # exactly 1 page
    with pytest.raises(PageAllocationError):
        p.extend(slot, 5)


def test_truncate_releases_pages_back_to_reservation():
    p = _pager(page_size=4)
    slot, pages = p.alloc_slot(prompt_len=6, max_new_tokens=8)
    # 6+8-1 = 13 tokens → 4 pages total, 2 drawn now, 2 reserved
    assert p.slot_reserved[slot] == 2
    p.extend(slot, 11)                      # verify run crossed a boundary
    assert p.pages_in_use == 3 and p.slot_reserved[slot] == 1
    released = p.truncate(slot, 8)          # rejected drafts → roll back
    assert released == 1
    assert p.pages_in_use == 2
    assert p.slot_reserved[slot] == 2       # page returned to the reserve
    assert int(p.slot_len[slot]) == 8
    assert p.page_tables[slot, 2] == 0      # table entry back to scratch
    p.extend(slot, 13)                      # rollback never blocks re-extend
    assert p.pages_in_use == 4
    p.free_slot(slot)
    assert p.pages_in_use == 0 and p.num_free_pages == 16


def test_truncate_within_page_keeps_mapping():
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=5, max_new_tokens=4)
    p.extend(slot, 7)
    assert p.truncate(slot, 6) == 0         # same page: nothing released
    assert int(p.slot_len[slot]) == 6
    p.free_slot(slot)


def test_truncate_guards():
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=6, max_new_tokens=6)
    p.slot_committed[slot] = 6              # prompt fully resident
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 5)                 # below the prompt watermark
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 99)                # growth is not a truncation
    with pytest.raises(PageAllocationError):
        p.truncate(slot + 1, 4)             # inactive slot
    # aliased/pinned pages are never rolled back: simulate a second owner
    # on the tail page (a pin) and ask for a rollback that would free it
    p.extend(slot, 11)                      # draws the 3rd page
    tail = p.slot_pages[slot][-1]
    p.page_ref[tail] += 1
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 8)
    assert int(p.slot_len[slot]) == 11      # guard fired before mutation
    p.page_ref[tail] -= 1
    assert p.truncate(slot, 8) == 1


def test_double_free_and_underflow_raise():
    p = _pager()
    slot, pages = p.alloc_slot(prompt_len=4, max_new_tokens=1)
    p.free_slot(slot)
    before = (len(p.free_pages), len(set(p.free_pages)))
    with pytest.raises(PageAllocationError):
        p.free_slot(slot)                   # double free of the slot
    with pytest.raises(RuntimeError):
        p._release_page(pages[0])           # refcount underflow
    # the failed frees never pushed a duplicate onto the free list
    assert (len(p.free_pages), len(set(p.free_pages))) == before
    assert len(p.free_pages) == len(set(p.free_pages))


def test_commit_scatter_matches_logical_order():
    """Gather(commit(dense)) reproduces the dense sequence, incl. partial
    last page."""
    page_size, n_pages, pages_per_slot = 4, 9, 2
    heads, hd, layers = 2, 3, 2
    s = 6                                      # 1 full page + 2-token partial
    rng = np.random.default_rng(0)
    k = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32)
    v = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32)
    cache = {"seg_0": {"kv_pool": {
        "k": jnp.zeros((layers, n_pages, page_size, heads, hd)),
        "v": jnp.zeros((layers, n_pages, page_size, heads, hd))}}}
    prefill = {"seg_0": {"kv": {"k": jnp.asarray(k), "v": jnp.asarray(v)}}}
    phys = jnp.asarray([5, 2], jnp.int32)
    out = commit_prefill(cache, prefill, jnp.int32(0), phys,
                         page_size=page_size)
    pool = out["seg_0"]["kv_pool"]["k"]
    table = np.zeros((1, pages_per_slot), np.int32)
    table[0, :2] = [5, 2]
    gathered = np.asarray(pool)[:, table[0]].reshape(layers, -1, heads, hd)
    np.testing.assert_array_equal(gathered[:, :s], k[:, 0])
    # pages not owned by the slot stay zero
    untouched = [i for i in range(n_pages) if i not in (5, 2)]
    assert not np.asarray(pool)[:, untouched].any()
