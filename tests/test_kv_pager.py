"""KVPager: page alloc/free/reuse accounting, spill/restore host tier,
optimistic admission, commit scatter layout."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_pager import (KVPager, PageAllocationError, PagerConfig,
                                    commit_prefill)


def _pager(num_pages=17, page_size=4, num_slots=4, pages_per_slot=4):
    return KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                               num_slots=num_slots,
                               pages_per_slot=pages_per_slot))


def test_alloc_free_roundtrip_accounting():
    p = _pager()
    assert p.num_free_pages == 16 and p.pages_in_use == 0
    slot, pages = p.alloc_slot(prompt_len=6, max_new_tokens=5)
    # 6-token prompt at P=4 → 2 pages now; 6+5-1=10 tokens → 3 total, 1 held
    assert len(pages) == 2
    assert p.pages_in_use == 2
    assert p.num_free_pages == 14
    assert p.slot_reserved[slot] == 1
    p.extend(slot, 9)                      # 9 tokens → 3rd page drawn
    assert p.pages_in_use == 3 and p.slot_reserved[slot] == 0
    p.free_slot(slot)
    assert p.pages_in_use == 0 and p.num_free_pages == 16
    assert p.num_free_slots == 4
    assert (p.page_tables[slot] == 0).all()   # back to scratch mapping


def test_page_exclusivity_and_reuse():
    p = _pager()
    s1, pg1 = p.alloc_slot(4, 1)
    s2, pg2 = p.alloc_slot(4, 1)
    assert not set(pg1) & set(pg2)
    assert 0 not in pg1 + pg2              # scratch page never handed out
    p.free_slot(s1)
    s3, pg3 = p.alloc_slot(8, 1)
    # LIFO free list: the freed page is reused first
    assert pg1[0] in pg3
    assert not set(pg3) & set(pg2)


def test_admission_respects_reservations():
    # 5 usable pages; first request reserves 4 (16 tokens worst case)
    p = _pager(num_pages=6, page_size=4, num_slots=2, pages_per_slot=4)
    s1, _ = p.alloc_slot(prompt_len=4, max_new_tokens=13)   # 16 tok → 4 pages
    assert p.slot_reserved[s1] == 3
    # one unreserved page left → an 8-token request must be refused
    assert not p.can_admit(prompt_len=5, max_new_tokens=4)
    assert p.can_admit(prompt_len=4, max_new_tokens=1)
    with pytest.raises(PageAllocationError):
        p.alloc_slot(prompt_len=5, max_new_tokens=4)
    # after the big request frees, admission succeeds again
    p.free_slot(s1)
    assert p.can_admit(prompt_len=5, max_new_tokens=4)


def test_over_capacity_request_rejected():
    p = _pager(pages_per_slot=2, page_size=4)   # 8-token slot capacity
    assert not p.can_admit(prompt_len=6, max_new_tokens=4)
    with pytest.raises(PageAllocationError):
        p.alloc_slot(6, 4)


def test_extend_cannot_outgrow_reservation():
    p = _pager()
    slot, _ = p.alloc_slot(prompt_len=4, max_new_tokens=1)  # exactly 1 page
    with pytest.raises(PageAllocationError):
        p.extend(slot, 5)


def test_truncate_releases_pages_back_to_reservation():
    p = _pager(page_size=4)
    slot, pages = p.alloc_slot(prompt_len=6, max_new_tokens=8)
    # 6+8-1 = 13 tokens → 4 pages total, 2 drawn now, 2 reserved
    assert p.slot_reserved[slot] == 2
    p.extend(slot, 11)                      # verify run crossed a boundary
    assert p.pages_in_use == 3 and p.slot_reserved[slot] == 1
    released = p.truncate(slot, 8)          # rejected drafts → roll back
    assert released == 1
    assert p.pages_in_use == 2
    assert p.slot_reserved[slot] == 2       # page returned to the reserve
    assert int(p.slot_len[slot]) == 8
    assert p.page_tables[slot, 2] == 0      # table entry back to scratch
    p.extend(slot, 13)                      # rollback never blocks re-extend
    assert p.pages_in_use == 4
    p.free_slot(slot)
    assert p.pages_in_use == 0 and p.num_free_pages == 16


def test_truncate_within_page_keeps_mapping():
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=5, max_new_tokens=4)
    p.extend(slot, 7)
    assert p.truncate(slot, 6) == 0         # same page: nothing released
    assert int(p.slot_len[slot]) == 6
    p.free_slot(slot)


def test_truncate_guards():
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=6, max_new_tokens=6)
    p.slot_committed[slot] = 6              # prompt fully resident
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 5)                 # below the prompt watermark
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 99)                # growth is not a truncation
    with pytest.raises(PageAllocationError):
        p.truncate(slot + 1, 4)             # inactive slot
    # aliased/pinned pages are never rolled back: simulate a second owner
    # on the tail page (a pin) and ask for a rollback that would free it
    p.extend(slot, 11)                      # draws the 3rd page
    tail = p.slot_pages[slot][-1]
    p.page_ref[tail] += 1
    with pytest.raises(PageAllocationError):
        p.truncate(slot, 8)
    assert int(p.slot_len[slot]) == 11      # guard fired before mutation
    p.page_ref[tail] -= 1
    assert p.truncate(slot, 8) == 1


def test_double_free_and_underflow_raise():
    p = _pager()
    slot, pages = p.alloc_slot(prompt_len=4, max_new_tokens=1)
    p.free_slot(slot)
    before = (len(p.free_pages), len(set(p.free_pages)))
    with pytest.raises(PageAllocationError):
        p.free_slot(slot)                   # double free of the slot
    with pytest.raises(RuntimeError):
        p._release_page(pages[0])           # refcount underflow
    # the failed frees never pushed a duplicate onto the free list
    assert (len(p.free_pages), len(set(p.free_pages))) == before
    assert len(p.free_pages) == len(set(p.free_pages))


def test_spill_restore_roundtrip_accounting():
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=6, max_new_tokens=7)   # 12 tok, 3 pages
    p.slot_committed[slot] = 6
    p.extend(slot, 9)                       # 3rd page drawn, 0 reserved left
    pages_before = list(p.slot_pages[slot])
    assert p.peek_spill(slot) == pages_before    # all exclusive → all spill
    rec = p.spill(slot)
    # the slot fully freed: pages back on the free list, slot reusable,
    # the record snapshots length/watermark/reservation exactly
    assert rec.spilled_pages == pages_before and rec.n_spilled == 3
    assert rec.slot_len == 9 and rec.committed == 6 and rec.reserved == 0
    assert p.pages_in_use == 0 and p.num_free_slots == 4
    assert p.stats().spill_records == 1 and p.stats().pages_spilled == 3
    assert p.can_restore(rec)
    slot2, fresh = p.restore(rec)
    assert len(fresh) == 3 and p.slot_pages[slot2] == fresh
    assert int(p.slot_len[slot2]) == 9 and p.slot_committed[slot2] == 6
    assert not p.spill_records
    p.extend(slot2, 12)                     # resumed decode still extends
    p.free_slot(slot2)
    assert p.pages_in_use == 0
    p.verify_invariants()


def test_spill_truncate_free_mutually_safe():
    """A spilled slot is inactive: every mutator raises BEFORE mutating,
    double spill/restore/drop raise, and the failed calls leave the
    accounting bit-identical."""
    p = _pager(page_size=4)
    slot, _ = p.alloc_slot(prompt_len=6, max_new_tokens=7)
    p.slot_committed[slot] = 6
    rec = p.spill(slot)
    snap = (list(p.free_pages), p.page_tables.copy(), p._reserved)
    for bad in (lambda: p.spill(slot),
                lambda: p.truncate(slot, 4),
                lambda: p.extend(slot, 9),
                lambda: p.commit_chunk(slot, 0, 4),
                lambda: p.free_slot(slot),
                lambda: p.peek_spill(slot)):
        with pytest.raises(PageAllocationError):
            bad()
    assert (list(p.free_pages), p._reserved) == (snap[0], snap[2])
    assert (p.page_tables == snap[1]).all()
    slot2, _ = p.restore(rec)
    for dead in (lambda: p.restore(rec), lambda: p.drop_spill(rec)):
        with pytest.raises(PageAllocationError):
            dead()
    p.free_slot(slot2)
    p.verify_invariants()


def test_spill_keeps_aliased_and_indexed_pages_resident():
    """Refcount>1 and prefix-indexed pages never leave the device: the
    record inherits the slot's refcount so sharing keeps working while
    the request is parked, and restore reattaches them in place."""
    p = _pager(num_pages=17, page_size=4)
    toks = np.arange(8, dtype=np.int32)
    s1, _ = p.alloc_slot(8, 3)              # 2 full prompt pages
    p.slot_committed[s1] = 8
    p.register_prefix(s1, toks, "ns")
    sh = p.match_prefix(toks, "ns")
    s2, _ = p.alloc_slot(8, 3, shared_pages=sh)
    assert p.shared_pages == 2
    p.extend(s2, 10)                        # one private decode page
    private = p.slot_pages[s2][-1]
    assert p.peek_spill(s2) == [private]    # aliased pages stay put
    rec = p.spill(s2)
    assert rec.layout == [("kept", sh[0]), ("kept", sh[1]),
                          ("spilled", 0)]
    # s1 still owns the shared pages (ref: s1 + parked record)
    assert all(int(p.page_ref[pg]) == 2 for pg in sh)
    p.verify_invariants()
    s2b, fresh = p.restore(rec)
    assert p.slot_pages[s2b] == [sh[0], sh[1], fresh[0]]
    assert int(p.slot_len[s2b]) == 10
    p.free_slot(s1)
    p.free_slot(s2b)
    p.unpin_prefix("ns")
    assert p.pages_in_use == 0
    p.verify_invariants()


def test_drop_spill_releases_kept_refcounts():
    p = _pager(num_pages=17, page_size=4)
    toks = np.arange(8, dtype=np.int32)
    s1, _ = p.alloc_slot(8, 3)
    p.slot_committed[s1] = 8
    p.register_prefix(s1, toks, "ns")
    s2, pg2 = p.alloc_slot(8, 3, shared_pages=p.match_prefix(toks, "ns"))
    rec = p.spill(s2)
    p.drop_spill(rec)                       # parked request cancelled
    assert all(int(p.page_ref[pg]) == 1 for pg in pg2)
    with pytest.raises(PageAllocationError):
        p.drop_spill(rec)
    with pytest.raises(PageAllocationError):
        p.restore(rec)
    p.free_slot(s1)
    assert p.pages_in_use == 0
    p.verify_invariants()


def test_restore_refused_without_capacity_and_mutates_nothing():
    p = _pager(num_pages=9, page_size=4, num_slots=3, pages_per_slot=4)
    s1, _ = p.alloc_slot(prompt_len=8, max_new_tokens=5)   # 2 drawn + 1 rsv
    p.slot_committed[s1] = 8
    rec = p.spill(s1)
    assert rec.n_spilled == 2 and rec.reserved == 1
    # soak the pool so the record's 2 pages + 1 reservation no longer fit
    s2, _ = p.alloc_slot(prompt_len=16, max_new_tokens=1)  # 4 drawn
    s3, _ = p.alloc_slot(prompt_len=12, max_new_tokens=2)  # 3 drawn + 1 rsv
    assert not p.can_restore(rec)
    snap = (list(p.free_pages), p._reserved, len(p.spill_records))
    with pytest.raises(PageAllocationError):
        p.restore(rec)
    assert (list(p.free_pages), p._reserved,
            len(p.spill_records)) == snap
    p.free_slot(s3)
    assert p.can_restore(rec)               # capacity back → restorable
    slot, _ = p.restore(rec)
    p.free_slot(slot)
    p.free_slot(s2)
    p.verify_invariants()


def test_optimistic_admission_and_free_pool_extend():
    """Optimistic mode: admission covers the prompt (plus one page of
    headroom), extend draws from the free pool, truncate does NOT
    re-credit a reservation, and a dry pool raises the pressure error."""
    p = KVPager(PagerConfig(num_pages=7, page_size=4, num_slots=2,
                            pages_per_slot=6, optimistic=True))
    # worst case 6 pages > pool, but prompt needs just 1 (+1 headroom)
    assert p.can_admit(prompt_len=4, max_new_tokens=20)
    slot, _ = p.alloc_slot(prompt_len=4, max_new_tokens=20)
    assert p.slot_reserved[slot] == 0 and p._reserved == 0
    p.slot_committed[slot] = 4
    p.extend(slot, 17)                      # 5 pages drawn from the pool
    assert p.pages_in_use == 5 and p.num_free_pages == 1
    assert p.truncate(slot, 12) == 2        # pages → free list, no reserve
    assert p.slot_reserved[slot] == 0 and p.num_free_pages == 3
    p.extend(slot, 23)                      # capacity cap: 6 pages
    with pytest.raises(PageAllocationError, match="free pool exhausted|"
                                                  "over capacity"):
        p.extend(slot, 25)
    p.free_slot(slot)
    p.verify_invariants()
    # second slot exhausts the pool mid-run → pressure error names it
    a, _ = p.alloc_slot(4, 20)
    b, _ = p.alloc_slot(4, 20)
    p.slot_committed[a] = p.slot_committed[b] = 4
    p.extend(a, 16)                         # 4 pages; pool: 6-4-1-1=0 left
    with pytest.raises(PageAllocationError, match="pressure relief"):
        p.extend(b, 9)
    p.verify_invariants()                   # partial-draw raise stays sound


def test_commit_scatter_matches_logical_order():
    """Gather(commit(dense)) reproduces the dense sequence, incl. partial
    last page."""
    page_size, n_pages, pages_per_slot = 4, 9, 2
    heads, hd, layers = 2, 3, 2
    s = 6                                      # 1 full page + 2-token partial
    rng = np.random.default_rng(0)
    k = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32)
    v = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32)
    cache = {"seg_0": {"kv_pool": {
        "k": jnp.zeros((layers, n_pages, page_size, heads, hd)),
        "v": jnp.zeros((layers, n_pages, page_size, heads, hd))}}}
    prefill = {"seg_0": {"kv": {"k": jnp.asarray(k), "v": jnp.asarray(v)}}}
    phys = jnp.asarray([5, 2], jnp.int32)
    out = commit_prefill(cache, prefill, jnp.int32(0), phys,
                         page_size=page_size)
    pool = out["seg_0"]["kv_pool"]["k"]
    table = np.zeros((1, pages_per_slot), np.int32)
    table[0, :2] = [5, 2]
    gathered = np.asarray(pool)[:, table[0]].reshape(layers, -1, heads, hd)
    np.testing.assert_array_equal(gathered[:, :s], k[:, 0])
    # pages not owned by the slot stay zero
    untouched = [i for i in range(n_pages) if i not in (5, 2)]
    assert not np.asarray(pool)[:, untouched].any()
